"""Tests for the radio-interferometer substrate (supplementary §7 pipeline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import niht, qniht, relative_error, support_recovery
from repro.sensing import (
    Station,
    dirty_beam,
    dirty_image,
    make_sky,
    measurement_matrix,
    sky_grid,
    visibilities,
)


class TestStation:
    def test_deterministic_layout(self):
        a = Station(n_antennas=10).antenna_positions()
        b = Station(n_antennas=10).antenna_positions()
        np.testing.assert_array_equal(a, b)

    def test_baseline_count_excludes_autocorr(self):
        st = Station(n_antennas=10)
        assert st.baselines().shape == (90, 2)
        st2 = Station(n_antennas=10, include_autocorrelations=True)
        assert st2.baselines().shape == (100, 2)

    def test_baselines_antisymmetric(self):
        st = Station(n_antennas=5)
        b = st.baselines().reshape(5, 4, 2)  # (i, k!=i) pairs, row-major
        full = np.zeros((5, 5, 2))
        p = st.antenna_positions() / st.wavelength
        full = p[:, None, :] - p[None, :, :]
        assert np.allclose(full, -full.transpose(1, 0, 2))


class TestPhi:
    def test_unit_modulus_entries(self):
        phi = measurement_matrix(Station(n_antennas=6), 8, extent=0.5)
        np.testing.assert_allclose(np.asarray(jnp.abs(phi)), 1.0, atol=1e-5)

    def test_shape(self):
        phi = measurement_matrix(Station(n_antennas=6), 8)
        assert phi.shape == (30, 64) and phi.dtype == jnp.complex64

    def test_conjugate_baseline_rows(self):
        """Rows for (i,k) and (k,i) are complex conjugates (u -> -u)."""
        st = Station(n_antennas=4)
        phi = np.asarray(measurement_matrix(st, 6, extent=0.7))
        b = st.baselines()
        # find a pair of opposite baselines
        i, j = 0, None
        for cand in range(1, len(b)):
            if np.allclose(b[cand], -b[0]):
                j = cand
                break
        assert j is not None
        np.testing.assert_allclose(phi[i], np.conj(phi[j]), atol=1e-5)

    def test_grid_extent(self):
        g = sky_grid(4, extent=0.3)
        assert g.min() == pytest.approx(-0.3) and g.max() == pytest.approx(0.3)


class TestSky:
    @pytest.mark.slow
    def test_source_count_and_range(self):
        x = make_sky(32, 7, jax.random.PRNGKey(0))
        assert int(jnp.sum(x > 0)) == 7
        assert float(jnp.min(x[x > 0])) >= 0.5 and float(jnp.max(x)) <= 1.0

    def test_min_separation(self):
        r, s, sep = 48, 10, 4
        x = make_sky(r, s, jax.random.PRNGKey(1), min_sep=sep)
        pos = np.argwhere(np.asarray(x.reshape(r, r)) > 0)
        for a in range(s):
            for b in range(a + 1, s):
                cheb = np.max(np.abs(pos[a] - pos[b]))
                assert cheb >= 2  # jitter keeps sources in distinct coarse cells

    def test_too_many_sources_raises(self):
        with pytest.raises(ValueError):
            make_sky(8, 100, jax.random.PRNGKey(2), min_sep=4)


class TestVisibilities:
    @pytest.mark.slow
    def test_snr_calibration(self):
        phi = measurement_matrix(Station(n_antennas=8), 12, extent=1.0)
        x = make_sky(12, 3, jax.random.PRNGKey(3), min_sep=3)
        y, e = visibilities(phi, x, 0.0, jax.random.PRNGKey(4))
        sig = phi @ x.astype(phi.dtype)
        snr = 10 * jnp.log10(jnp.real(jnp.vdot(sig, sig)) / jnp.real(jnp.vdot(e, e)))
        assert abs(float(snr)) < 1.5  # 0 dB within statistical wiggle

    def test_noiseless(self):
        phi = measurement_matrix(Station(n_antennas=6), 8)
        x = make_sky(8, 2, jax.random.PRNGKey(5), min_sep=2)
        y, e = visibilities(phi, x, None, jax.random.PRNGKey(6))
        assert float(jnp.max(jnp.abs(e))) == 0.0


class TestDirtyImage:
    def test_beam_peaks_at_center(self):
        r = 16
        phi = measurement_matrix(Station(n_antennas=10), r, extent=1.0)
        db = np.asarray(dirty_beam(phi, r))
        assert np.unravel_index(np.argmax(np.abs(db)), db.shape) == (r // 2, r // 2)

    @pytest.mark.slow
    def test_dirty_image_sees_source(self):
        r = 24
        phi = measurement_matrix(Station(n_antennas=16), r, extent=1.2)
        x = make_sky(r, 1, jax.random.PRNGKey(7), min_sep=2)
        y, _ = visibilities(phi, x, 20.0, jax.random.PRNGKey(8))
        di = np.asarray(dirty_image(phi, y, r))
        true = np.unravel_index(np.argmax(np.asarray(x.reshape(r, r))), (r, r))
        got = np.unravel_index(np.argmax(np.abs(di)), (r, r))
        assert max(abs(true[0] - got[0]), abs(true[1] - got[1])) <= 1


class TestEndToEndRecovery:
    """The paper's headline (Fig. 1): 2&8-bit recovery ~ 32-bit recovery at 0 dB."""

    @pytest.mark.slow
    def test_sky_recovery_low_precision(self):
        key = jax.random.PRNGKey(9)
        st = Station(n_antennas=30)
        r, s = 32, 8
        phi = measurement_matrix(st, r, extent=1.5)
        x = make_sky(r, s, key, min_sep=4)
        y, _ = visibilities(phi, x, 0.0, key)
        r32 = niht(phi, y, s, n_iters=40, real_signal=True, nonneg=True)
        r28 = qniht(phi, y, s, n_iters=40, bits_phi=2, bits_y=8, key=key,
                    real_signal=True, nonneg=True)
        e32 = float(relative_error(r32.x, x))
        e28 = float(relative_error(r28.x, x))
        assert float(support_recovery(r32.x, x, s)) == 1.0
        assert float(support_recovery(r28.x, x, s)) >= 0.85
        assert e28 <= e32 + 0.15  # negligible loss of recovery quality
