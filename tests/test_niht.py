"""System-behaviour tests for NIHT / QNIHT (the paper's Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import (
    eps_q,
    eps_s,
    niht,
    qniht,
    relative_error,
    rics_sampled,
    support_recovery,
    theorem3_bound,
)
from repro.sensing import make_gaussian_problem


class TestNIHT:
    def test_noiseless_exact_recovery(self):
        prob = make_gaussian_problem(128, 256, 8, snr_db=None, key=jax.random.PRNGKey(0))
        res = niht(prob.phi, prob.y, prob.s, n_iters=60)
        assert float(relative_error(res.x, prob.x_true)) < 1e-4
        assert float(support_recovery(res.x, prob.x_true, prob.s)) == 1.0

    def test_noisy_recovery(self):
        prob = make_gaussian_problem(128, 256, 8, snr_db=20.0, key=jax.random.PRNGKey(1))
        res = niht(prob.phi, prob.y, prob.s, n_iters=60)
        assert float(relative_error(res.x, prob.x_true)) < 0.1

    def test_support_invariant(self):
        """||x^[n]||_0 <= s at every iteration (H_s projection invariant)."""
        prob = make_gaussian_problem(64, 128, 5, snr_db=15.0, key=jax.random.PRNGKey(2))
        res = niht(prob.phi, prob.y, prob.s, n_iters=30)
        assert int(jnp.sum(jnp.abs(res.x) > 0)) <= prob.s

    def test_residual_decreases(self):
        """The quantized-cost trace should be (weakly) decreasing overall."""
        prob = make_gaussian_problem(128, 256, 8, snr_db=25.0, key=jax.random.PRNGKey(3))
        res = niht(prob.phi, prob.y, prob.s, n_iters=40)
        r = np.asarray(res.trace.resid_q)
        assert r[-1] <= r[0]
        # allow small non-monotonic blips, require 90% of steps non-increasing
        frac = np.mean(np.diff(r) <= 1e-4 * r[0])
        assert frac > 0.9

    @pytest.mark.slow
    def test_scale_invariance(self):
        """NIHT is scale-invariant in Phi (Remark 1): scaling Phi & y together
        changes nothing; scaling only Phi rescales x by 1/scale."""
        prob = make_gaussian_problem(96, 192, 6, snr_db=None, key=jax.random.PRNGKey(4))
        res1 = niht(prob.phi, prob.y, prob.s, n_iters=50)
        res2 = niht(prob.phi * 7.5, prob.y * 7.5, prob.s, n_iters=50)
        np.testing.assert_allclose(
            np.asarray(res1.x), np.asarray(res2.x), rtol=1e-3, atol=1e-5
        )

    @given(seed=st.integers(0, 30))
    @settings(max_examples=8, deadline=None)
    def test_backtracking_accepts(self, seed):
        """Property: the accepted step never leaves the run in a divergent state
        (residual stays finite, support stays <= s)."""
        prob = make_gaussian_problem(48, 96, 4, snr_db=10.0, key=jax.random.PRNGKey(seed))
        res = niht(prob.phi, prob.y, prob.s, n_iters=15)
        assert np.isfinite(np.asarray(res.trace.resid_q)).all()
        assert int(jnp.sum(jnp.abs(res.x) > 0)) <= prob.s


class TestQNIHT:
    @pytest.mark.slow
    def test_8bit_matches_full_precision(self):
        prob = make_gaussian_problem(128, 256, 8, snr_db=25.0, key=jax.random.PRNGKey(5))
        r32 = niht(prob.phi, prob.y, prob.s, n_iters=40)
        r8 = qniht(prob.phi, prob.y, prob.s, n_iters=40, bits_phi=8, bits_y=8,
                   key=jax.random.PRNGKey(6))
        e32 = float(relative_error(r32.x, prob.x_true))
        e8 = float(relative_error(r8.x, prob.x_true))
        assert e8 < e32 + 0.05  # negligible loss (paper Fig. 11)

    def test_requires_key(self):
        prob = make_gaussian_problem(32, 64, 3, key=jax.random.PRNGKey(7))
        with pytest.raises(ValueError):
            qniht(prob.phi, prob.y, prob.s, bits_phi=4)

    @pytest.mark.slow
    def test_pair_vs_fixed_modes_run(self):
        prob = make_gaussian_problem(64, 128, 4, snr_db=20.0, key=jax.random.PRNGKey(8))
        for mode in ("pair", "fixed"):
            res = qniht(prob.phi, prob.y, prob.s, n_iters=20, bits_phi=4, bits_y=8,
                        key=jax.random.PRNGKey(9), requantize=mode)
            assert np.isfinite(np.asarray(res.trace.resid_true)).all()

    @pytest.mark.slow
    def test_theorem3_bound_holds(self):
        """E||x^ - x^s|| <= 2^-n ||x^s|| + 10 eps_s + 5 eps_q  (Theorem 3).
        Statistical check with sampled RICs on a well-conditioned instance."""
        key = jax.random.PRNGKey(10)
        prob = make_gaussian_problem(256, 384, 4, snr_db=25.0, key=key)
        _, beta = rics_sampled(prob.phi, 2 * prob.s, 16, key)
        n_iters = 25
        res = qniht(prob.phi, prob.y, prob.s, n_iters=n_iters, bits_phi=8, bits_y=8, key=key)
        err = float(jnp.linalg.norm(res.x - prob.x_true))
        e_norm = float(jnp.linalg.norm(prob.e))
        es = float(eps_s(prob.x_true, prob.s, e_norm, float(beta)))
        eq = eps_q(
            prob.phi.shape[0], float(beta), float(jnp.linalg.norm(prob.x_true)), 8, 8,
            c_phi=float(jnp.max(jnp.abs(prob.phi))), c_y=float(jnp.max(jnp.abs(prob.y))),
        )
        bound = theorem3_bound(n_iters, float(jnp.linalg.norm(prob.x_true)), es, eq)
        assert err <= bound

    @pytest.mark.slow
    def test_quantized_y_only(self):
        prob = make_gaussian_problem(96, 192, 6, snr_db=20.0, key=jax.random.PRNGKey(11))
        res = qniht(prob.phi, prob.y, prob.s, n_iters=30, bits_y=8, key=jax.random.PRNGKey(12))
        assert float(relative_error(res.x, prob.x_true)) < 0.15

    def test_real_signal_projection(self):
        prob = make_gaussian_problem(64, 128, 4, snr_db=20.0, key=jax.random.PRNGKey(13))
        res = niht(prob.phi, prob.y, prob.s, n_iters=20, real_signal=True, nonneg=True)
        assert res.x.dtype == jnp.float32
        assert float(jnp.min(res.x)) >= 0.0


class TestBatchedPackedStreaming:
    """Regression guard for the serving amortization: ``qniht_batch`` with the
    packed backend must hand the WHOLE (B, ·) block to every packed operator
    application — one codes stream per iteration step, never one per row."""

    @staticmethod
    def _traced_batch_dims(batch):
        import repro.core.operators as op_mod
        from repro.core import qniht_batch

        real_mv, real_rmv = op_mod.packed_matvec, op_mod.packed_rmatvec
        mv_dims, rmv_dims = [], []

        def spy_mv(op, x, **kw):
            mv_dims.append(x.shape[0] if x.ndim == 2 else 1)
            return real_mv(op, x, **kw)

        def spy_rmv(op, r, **kw):
            rmv_dims.append(r.shape[0] if r.ndim == 2 else 1)
            return real_rmv(op, r, **kw)

        op_mod.packed_matvec, op_mod.packed_rmatvec = spy_mv, spy_rmv
        try:
            # odd shape so no earlier test's jit cache hides the trace
            prob = make_gaussian_problem(37, 74, 4, snr_db=20.0,
                                         key=jax.random.PRNGKey(21))
            Y = jnp.stack([prob.y] * batch)
            qniht_batch(prob.phi, Y, 4, 3, bits_phi=8, bits_y=8,
                        key=jax.random.PRNGKey(22), requantize="fixed",
                        backend="packed", with_trace=False)
        finally:
            op_mod.packed_matvec, op_mod.packed_rmatvec = real_mv, real_rmv
        return mv_dims, rmv_dims

    def test_streams_codes_once_per_application(self):
        mv_dims, rmv_dims = self._traced_batch_dims(5)
        assert mv_dims and rmv_dims
        assert all(b == 5 for b in mv_dims), mv_dims
        assert all(b == 5 for b in rmv_dims), rmv_dims

    def test_application_count_independent_of_batch(self):
        mv3, rmv3 = self._traced_batch_dims(3)
        mv6, rmv6 = self._traced_batch_dims(6)
        assert (len(mv3), len(rmv3)) == (len(mv6), len(rmv6))
