"""Fault-injection matrix (slow tier): real SIGTERM kills + restarts.

Each test launches the actual CLIs as subprocesses, kills one mid-stream with
``SIGTERM``, restarts with ``--resume``, and pins the acceptance criterion of
the preemption-safe recovery path: the restarted run's output is
**bit-identical** to an uninterrupted run — across dense and packed backends,
single- and multi-device meshes, and a mesh-width change between save and
resume (elastic). The in-process equivalents (simulated guards, torn
checkpoints) run in the fast tier (``tests/test_resilience.py``).
"""
import os
import re
import signal
import subprocess
import sys

import numpy as np
import pytest

pytestmark = pytest.mark.slow

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return env


def _digests(text):
    return re.findall(r"chunk (\d+) x_digest=([0-9a-f]+)", text)


def _run(cmd, timeout=600):
    return subprocess.run(cmd, env=_env(), cwd=_REPO, capture_output=True,
                          text=True, timeout=timeout)


def _kill_after_first_digest(cmd, timeout=600):
    """Start a serve run, SIGTERM it right after its first chunk digest line,
    and return its full stdout (the guard finishes the in-flight chunk and
    exits cleanly at the boundary)."""
    p = subprocess.Popen(cmd, env=_env(), cwd=_REPO, stdout=subprocess.PIPE,
                         stderr=subprocess.PIPE, text=True)
    head = []
    for line in p.stdout:
        head.append(line)
        if "x_digest=" in line:
            p.send_signal(signal.SIGTERM)
            break
    rest, err = p.communicate(timeout=timeout)
    assert p.returncode == 0, (p.returncode, err[-3000:])
    return "".join(head) + rest


@pytest.mark.parametrize("config,devices", [
    ("serve-gaussian-fault", None),
    ("serve-gaussian-fault", 2),
    ("serve-gaussian-fault-packed", None),
    ("serve-gaussian-fault-packed", 2),
])
def test_serve_kill_resume_stream_parity(tmp_path, config, devices):
    """kill -TERM during chunk k of the n-chunk serve + restart --resume →
    the full per-chunk result stream (sha256 of each chunk's x) is identical
    to the uninterrupted run's."""
    base = [sys.executable, "-m", "repro.launch.serve", "--config", config]
    if devices:
        base += ["--devices", str(devices)]
    d_ref, d_kill = str(tmp_path / "ref"), str(tmp_path / "kill")

    ref = _run(base + ["--checkpoint-dir", d_ref])
    assert ref.returncode == 0, ref.stderr[-3000:]
    ref_d = _digests(ref.stdout)
    assert len(ref_d) == 5, ref.stdout

    out = _kill_after_first_digest(base + ["--checkpoint-dir", d_kill])
    assert "preempted after chunk" in out, out
    killed = _digests(out)
    assert 1 <= len(killed) < 5, killed
    assert killed == ref_d[:len(killed)]  # journaled prefix already matches

    res = _run(base + ["--checkpoint-dir", d_kill, "--resume"])
    assert res.returncode == 0, res.stderr[-3000:]
    assert _digests(res.stdout) == ref_d
    assert f"chunks_drained={len(killed)}" in res.stdout, res.stdout


def _final_ckpt_leaves(d):
    steps = sorted(s for s in os.listdir(d)
                   if s.startswith("step_") and not s.endswith(".tmp"))
    top = os.path.join(d, steps[-1])
    return {f: np.load(os.path.join(top, f))
            for f in sorted(os.listdir(top)) if f.endswith(".npy")}


def test_recover_kill_elastic_resume_bitwise(tmp_path):
    """Segmented recover killed mid-run at --devices 4 and resumed at
    --devices 2 (elastic): final checkpointed SolverState is byte-identical to
    the uninterrupted 4-device run's."""
    base = [sys.executable, "-m", "repro.launch.recover", "--config",
            "gaussian-smoke", "--backend", "packed", "--bits-phi", "4",
            "--bits-y", "8", "--batch", "8", "--ckpt-every", "5"]
    d_ref, d_kill = str(tmp_path / "ref"), str(tmp_path / "kill")

    ref = _run(base + ["--devices", "4", "--checkpoint-dir", d_ref])
    assert ref.returncode == 0, ref.stderr[-3000:]
    assert "[recover]" in ref.stdout

    p = subprocess.Popen(base + ["--devices", "4", "--checkpoint-dir", d_kill],
                         env=_env(), cwd=_REPO, stdout=subprocess.PIPE,
                         stderr=subprocess.PIPE, text=True)
    for line in p.stdout:
        if "checkpointed" in line:
            p.send_signal(signal.SIGTERM)
            break
    rest, err = p.communicate(timeout=600)
    assert p.returncode == 0, (p.returncode, err[-3000:])
    assert "preempted at iteration" in rest, rest

    res = _run(base + ["--devices", "2", "--checkpoint-dir", d_kill, "--resume"])
    assert res.returncode == 0, res.stderr[-3000:]
    assert "resumed from step" in res.stdout, res.stdout

    a, b = _final_ckpt_leaves(d_ref), _final_ckpt_leaves(d_kill)
    assert a.keys() == b.keys()
    for name in a:
        np.testing.assert_array_equal(a[name], b[name], err_msg=name)


def test_recover_single_problem_resume(tmp_path):
    """Single-observation path (no --batch): preempt + resume matches the
    uninterrupted checkpointed run's reported metrics exactly."""
    base = [sys.executable, "-m", "repro.launch.recover", "--config",
            "gaussian-smoke", "--backend", "fake", "--bits-phi", "4",
            "--bits-y", "8", "--ckpt-every", "4"]
    d_ref, d_kill = str(tmp_path / "ref"), str(tmp_path / "kill")

    ref = _run(base + ["--checkpoint-dir", d_ref])
    assert ref.returncode == 0, ref.stderr[-3000:]
    ref_line = [ln for ln in ref.stdout.splitlines() if "[recover]" in ln][-1]

    p = subprocess.Popen(base + ["--checkpoint-dir", d_kill], env=_env(),
                         cwd=_REPO, stdout=subprocess.PIPE,
                         stderr=subprocess.PIPE, text=True)
    for line in p.stdout:
        if "checkpointed" in line:
            p.send_signal(signal.SIGTERM)
            break
    rest, err = p.communicate(timeout=600)
    assert p.returncode == 0, (p.returncode, err[-3000:])
    assert "preempted at iteration" in rest

    res = _run(base + ["--checkpoint-dir", d_kill, "--resume"])
    assert res.returncode == 0, res.stderr[-3000:]
    res_line = [ln for ln in res.stdout.splitlines() if "[recover]" in ln][-1]
    assert res_line == ref_line
