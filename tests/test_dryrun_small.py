"""Dry-run machinery tests, scaled to CI: lower+compile smoke configs on a
small host-device mesh in a subprocess (the production 512-device sweep runs
via scripts/run_dryruns.py; this validates the same code path)."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
# importing repro.launch.dryrun sets XLA_FLAGS to 512 placeholder devices (its
# production default); import it FIRST, then pin the CI-sized count before the
# first jax device query locks the backend.
from repro.launch.dryrun import collective_bytes_from_hlo, _cost_analysis, _serve_abstracts
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_smoke_config
from repro.optim import adamw
from repro.quant.policy import QuantPolicy, W4KV8
from repro.train.steps import (
    build_sharded_decode_step, build_sharded_prefill, build_sharded_train_step,
    init_state, train_input_specs,
)
from repro.models import model as M

mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))

# --- train step lowers, compiles, reports cost + collectives -------------
cfg = get_smoke_config("starcoder2_3b")
opt = adamw(1e-3)
step, st_sh = build_sharded_train_step(cfg, mesh, opt, global_batch=8)
state_abs = jax.eval_shape(lambda: init_state(cfg, opt, jax.random.PRNGKey(0)))
batch_abs = train_input_specs(cfg, mesh, 8, 32)
lowered = step.lower(state_abs, batch_abs)
compiled = lowered.compile()
cost = _cost_analysis(compiled)
assert cost.get("flops", 0) > 0, cost
coll = collective_bytes_from_hlo(compiled.as_text(), loop_trip=2)
assert coll["total"] > 0, coll     # DP gradient sync must appear

# --- decode step with quantized weights lowers on the multi-pod mesh -----
cfg2 = get_smoke_config("qwen1_5_32b")
dstep, _ = build_sharded_decode_step(cfg2, mesh, global_batch=8, cache_len=64,
                                     policy=W4KV8)
params_abs, cache_abs, _ = _serve_abstracts(cfg2, W4KV8, 8, 64)
tok = jax.ShapeDtypeStruct((8,), jnp.int32)
pos = jax.ShapeDtypeStruct((), jnp.int32)
dcomp = dstep.lower(params_abs, tok, cache_abs, pos).compile()
assert _cost_analysis(dcomp).get("flops", 0) > 0

# --- ssm decode (attention-free) lowers too -------------------------------
cfg3 = get_smoke_config("mamba2_370m")
sstep, _ = build_sharded_decode_step(cfg3, mesh, global_batch=8, cache_len=64)
p_abs, c_abs, _ = _serve_abstracts(cfg3, QuantPolicy(), 8, 64)
scomp = sstep.lower(p_abs, tok, c_abs, pos).compile()
print("DRYRUN_SMALL_OK")
"""


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560, cwd=_ROOT)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "DRYRUN_SMALL_OK" in res.stdout


def test_collective_parser():
    from repro.launch.dryrun import _shape_bytes, collective_bytes_from_hlo

    assert _shape_bytes("f32[16,4]") == 256
    assert _shape_bytes("bf16[8]{0}") == 16
    assert _shape_bytes("(f32[4], s32[2])") == 24
    hlo = """
ENTRY %main (a: f32[8]) -> f32[8] {
  %ar = f32[8]{0} all-reduce(%a), replica_groups={}
  ROOT %r = f32[8] copy(%ar)
}
%while_body.1 (p: f32[4]) -> f32[4] {
  %ag = f32[4]{0} all-gather(%p), dimensions={0}
}
"""
    out = collective_bytes_from_hlo(hlo, loop_trip=10)
    assert out["all-reduce"] == 32
    assert out["all-gather"] == 16 * 10  # body multiplied by trip count
    assert out["op_count"] == 2


def test_applicability_matrix():
    from repro.configs import ARCH_IDS, applicable, get_config
    from repro.configs.shapes import ALL_SHAPES

    runnable = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            ok, why = applicable(cfg, shape)
            if ok:
                runnable += 1
            else:
                assert shape.name == "long_500k" and why
    # 10 archs x 4 shapes - 8 long_500k skips (only ssm + hybrid run it)
    assert runnable == 32
