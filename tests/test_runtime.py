"""Runtime tests: optimizer, IHT sparsifier, data determinism, fault handling,
sharding rules, end-to-end training integration (loss decreases; restart
resumes bit-exactly)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.data import SyntheticStream, synthetic_batch
from repro.optim import IHTConfig, adamw, cosine_schedule, project_params, sparsity_report
from repro.parallel.collectives import fake_grad_compression
from repro.parallel.sharding import batch_spec, spec_for_path
from repro.train import (
    LoopConfig,
    TrainState,
    init_state,
    make_train_step,
    run_with_restarts,
    train_loop,
)


class TestAdamW:
    @pytest.mark.slow
    def test_quadratic_convergence(self):
        opt = adamw(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
            params, state, m = opt.update(grads, state, params)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2

    def test_grad_clip(self):
        opt = adamw(lr=0.1, grad_clip=1.0)
        params = {"w": jnp.zeros((4,))}
        state = opt.init(params)
        _, _, m = opt.update({"w": jnp.full((4,), 100.0)}, state, params)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=100)
        assert float(lr(jnp.asarray(0))) == pytest.approx(0.0)
        assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, rel=0.01)
        assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, rel=0.05)


class TestIHTSparsifier:
    def test_projection_sparsity(self):
        key = jax.random.PRNGKey(0)
        params = {"layer": {"w": jax.random.normal(key, (128, 64))}}
        cfg = IHTConfig(sparsity=0.75, min_size=1024)
        out = project_params(params, cfg)
        frac = float(jnp.mean(out["layer"]["w"] == 0))
        assert 0.70 <= frac <= 0.80
        assert sparsity_report(out, cfg) == pytest.approx(frac, abs=1e-6)

    def test_small_and_norm_leaves_untouched(self):
        params = {"ln": {"scale": jnp.ones((64,))},
                  "tiny": {"w": jnp.ones((4, 4))}}
        out = project_params(params, IHTConfig(sparsity=0.9, min_size=1024))
        assert float(jnp.min(out["ln"]["scale"])) == 1.0
        assert float(jnp.min(out["tiny"]["w"])) == 1.0

    def test_keeps_largest(self):
        w = jnp.arange(1.0, 4097.0).reshape(64, 64)
        out = project_params({"m": {"w": w}}, IHTConfig(sparsity=0.5, min_size=1))
        kept = out["m"]["w"]
        assert float(kept[-1, -1]) == 4096.0  # largest survives
        assert float(kept[0, 0]) == 0.0       # smallest zeroed


class TestData:
    def test_deterministic_replay(self):
        a = synthetic_batch(jax.random.PRNGKey(1), 7, 4, 16, 100)
        b = synthetic_batch(jax.random.PRNGKey(1), 7, 4, 16, 100)
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))

    def test_labels_are_next_tokens(self):
        b = synthetic_batch(jax.random.PRNGKey(2), 0, 2, 8, 50)
        assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)
        assert int(b["tokens"].max()) < 50

    def test_steps_differ(self):
        a = synthetic_batch(jax.random.PRNGKey(1), 0, 4, 16, 100)
        b = synthetic_batch(jax.random.PRNGKey(1), 1, 4, 16, 100)
        assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


class TestGradCompression:
    def test_unbiased(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(3), (32,))}
        keys = jax.random.split(jax.random.PRNGKey(4), 2000)
        outs = jax.vmap(lambda k: fake_grad_compression(g, 8, k)["w"])(keys)
        np.testing.assert_allclose(np.asarray(outs.mean(0)), np.asarray(g["w"]),
                                   atol=0.02)

    def test_error_bounded(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(5), (64,))}
        out = fake_grad_compression(g, 8, jax.random.PRNGKey(6))
        scale = float(jnp.max(jnp.abs(g["w"])))
        assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) <= scale / 64 + 1e-6


class TestShardingRules:
    def _mesh(self):
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        return Mesh(dev, ("data", "model"))

    def test_attention_rules(self):
        mesh = self._mesh()
        assert spec_for_path("slots/slot0/attn/wq/w", (2, 64, 64), mesh) == P(None, "data", "model")
        assert spec_for_path("slots/slot0/attn/wo/w", (2, 64, 64), mesh) == P(None, "model", "data")
        assert spec_for_path("embed/w", (512, 64), mesh) == P("model", "data")

    def test_indivisible_falls_back_to_replication(self):
        dev = np.array(jax.devices() * 1)[:1].reshape(1, 1)
        mesh = Mesh(dev, ("data", "model"))
        # mesh axis size 1 divides everything; simulate a fat axis via a fake
        # mesh by checking the rule logic directly on odd dims
        from repro.parallel.sharding import _divisible

        assert not _divisible(7, None, mesh)

    def test_norms_replicated(self):
        mesh = self._mesh()
        assert spec_for_path("final_norm/scale", (64,), mesh) == P()

    def test_moe_expert_parallel(self):
        mesh = self._mesh()
        assert spec_for_path("slots/slot0/ffn/wi_gate", (2, 8, 64, 32), mesh) == \
            P(None, "model", "data", None)

    def test_batch_spec(self):
        mesh = self._mesh()
        assert batch_spec(mesh, 8, 2) == P(("data",), None) or \
            batch_spec(mesh, 8, 2) == P("data", None)


@pytest.mark.slow
class TestTrainIntegration:
    def _setup(self):
        cfg = get_smoke_config("starcoder2_3b")
        opt = adamw(3e-3)
        state = init_state(cfg, opt, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, opt))

        def stepper(state, batch):
            batch = dict(batch)
            batch["memory"] = None
            return step(state, batch)

        stream = SyntheticStream(0, 8, 32, cfg.vocab_size)
        return cfg, stepper, state, stream

    def test_loss_decreases(self):
        cfg, step, state, stream = self._setup()
        first = last = None
        for i in range(25):
            state, m = step(state, stream.at_step(i))
            if first is None:
                first = float(m["loss"])
            last = float(m["loss"])
        assert last < first - 0.2

    def test_restart_resumes_bit_exact(self, tmp_path):
        """Kill training mid-run; the restarted loop must continue to the same
        final loss as an uninterrupted run (deterministic data + checkpoints)."""
        cfg, step, state0, stream = self._setup()
        loop_cfg = LoopConfig(total_steps=12, ckpt_dir=str(tmp_path),
                              ckpt_every=4, ckpt_async=False, log_every=100)

        # uninterrupted reference
        ref_state = train_loop(step, state0, stream, loop_cfg, log=lambda s: None)

        # interrupted: run 6 steps (crash), then resume via the loop itself
        crash_dir = str(tmp_path / "crashy")
        os.makedirs(crash_dir)
        c_cfg = LoopConfig(total_steps=12, ckpt_dir=crash_dir, ckpt_every=4,
                           ckpt_async=False, log_every=100)

        calls = {"n": 0}

        def body(attempt):
            calls["n"] += 1
            if attempt == 0:
                # run 6 steps then die (after the step-4 checkpoint exists)
                partial_cfg = LoopConfig(total_steps=6, ckpt_dir=crash_dir,
                                         ckpt_every=4, ckpt_async=False, log_every=100)
                train_loop(step, state0, stream, partial_cfg, log=lambda s: None)
                raise RuntimeError("injected node failure")
            return train_loop(step, state0, stream, c_cfg, log=lambda s: None)

        final = run_with_restarts(body, max_restarts=2)
        assert calls["n"] == 2
        a = jax.tree.leaves(ref_state.params)
        b = jax.tree.leaves(final.params)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestIHTTiePlateau:
    def test_constant_matrix_keeps_budget(self):
        """Tie-degeneracy regression (same bug class as the solver's H_s): a
        constant plateau must keep `keep` entries, not be zeroed wholesale."""
        from repro.optim.iht import _project_matrix

        w = jnp.ones((64, 64))
        out = _project_matrix(w, keep=2048)
        assert int(jnp.sum(out != 0)) == 2048

    def test_distinct_magnitudes_unchanged_semantics(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
        from repro.optim.iht import _project_matrix

        out = _project_matrix(w, keep=1024)
        n = int(jnp.sum(out != 0))
        assert 1024 - 8 <= n <= 1024  # bin ties only
        kept_min = float(jnp.min(jnp.abs(out[out != 0])))
        dropped_max = float(jnp.max(jnp.abs(jnp.where(out == 0, w, 0.0))))
        assert kept_min >= dropped_max - float(jnp.max(jnp.abs(w))) / 4096
