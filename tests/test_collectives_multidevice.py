"""Multi-device collective tests — run in a subprocess with 8 host devices so
the main pytest process keeps its single-device view (per the dry-run rules).

``test_qgrad_allreduce_host_mesh`` is the fast in-process regression for the
shard_map entry point (JAX 0.4.x has it under ``jax.experimental.shard_map``,
not ``jax.shard_map``) so an import/dispatch break surfaces in the quick tier,
not only in the slow subprocess test."""
import os
import subprocess
import sys

import numpy as np
import pytest


def test_qgrad_allreduce_host_mesh():
    import jax
    from jax.sharding import Mesh
    from repro.parallel.collectives import make_qgrad_allreduce

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1,), ("pod",))
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (1, 16)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (1, 4))}
    out = make_qgrad_allreduce(mesh, "pod", 8)(tree, jax.random.fold_in(key, 2))
    for k in tree:
        exp = np.asarray(tree[k]).mean(0)
        got = np.asarray(out[k])[0]
        scale = np.abs(np.asarray(tree[k])).max()
        assert np.abs(got - exp).max() <= scale / 64, k

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.parallel.collectives import make_qgrad_allreduce

mesh = Mesh(np.array(jax.devices()).reshape(8,), ("pod",))
key = jax.random.PRNGKey(0)
tree = {"w": jax.random.normal(key, (8, 16)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (8, 4))}
ar = make_qgrad_allreduce(mesh, "pod", 8)
out = ar(tree, jax.random.fold_in(key, 2))
for k in tree:
    exp = np.asarray(tree[k]).mean(0)
    got = np.asarray(out[k])[0]
    scale = np.abs(np.asarray(tree[k])).max()
    assert np.abs(got - exp).max() <= scale / 64, k

# elasticity: the same pytree under a 4-device sub-mesh still reduces correctly
mesh4 = Mesh(np.array(jax.devices()[:4]).reshape(4,), ("pod",))
tree4 = {"w": jax.random.normal(key, (4, 16))}
out4 = make_qgrad_allreduce(mesh4, "pod", 8)(tree4, key)
exp4 = np.asarray(tree4["w"]).mean(0)
assert np.abs(np.asarray(out4["w"])[0] - exp4).max() <= float(np.abs(np.asarray(tree4["w"])).max()) / 64

# sharded-batch training sanity: pjit a tiny step over a (2, 4) mesh
from repro.configs import get_smoke_config
from repro.optim import adamw
from repro.train import init_state, make_train_step
from repro.train.steps import build_sharded_train_step
cfg = get_smoke_config("starcoder2_3b")
mesh2 = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
opt = adamw(1e-3)
step, st_sh = build_sharded_train_step(cfg, mesh2, opt, global_batch=8)
state = init_state(cfg, opt, key)
state = jax.device_put(state, st_sh)
batch = {
    "tokens": jnp.zeros((8, 32), jnp.int32),
    "labels": jnp.zeros((8, 32), jnp.int32),
    "memory": None,
}
state, metrics = step(state, batch)
assert jnp.isfinite(metrics["loss"])
print("MULTIDEVICE_OK")
"""


@pytest.mark.slow
def test_quantized_allreduce_and_sharded_step_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True, text=True,
        timeout=420, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "MULTIDEVICE_OK" in res.stdout
