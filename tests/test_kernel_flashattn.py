"""Pallas flash attention: interpret-mode allclose sweeps vs the naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.kernels.flashattn.ops import flash_attention
from repro.kernels.flashattn.ref import attention_ref


def _rand_qkv(key, b, hq, hkv, sq, sk, d, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, sq, d)).astype(dtype)
    k = jax.random.normal(kk, (b, hkv, sk, d)).astype(dtype)
    v = jax.random.normal(kv, (b, hkv, sk, d)).astype(dtype)
    return q, k, v


class TestFlashVsOracle:
    @given(
        causal=st.booleans(),
        b=st.integers(1, 3),
        h=st.sampled_from([1, 2, 4]),
        s_blocks=st.integers(1, 4),
        d=st.sampled_from([32, 64]),
        seed=st.integers(0, 99),
    )
    @settings(max_examples=20, deadline=None)
    def test_shape_sweep(self, causal, b, h, s_blocks, d, seed):
        s = 64 * s_blocks
        q, k, v = _rand_qkv(jax.random.PRNGKey(seed), b, h, h, s, s, d)
        out = flash_attention(q, k, v, causal=causal, use_pallas=True,
                              interpret=True, block_q=64, block_k=64)
        ref = flash_attention(q, k, v, causal=causal, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("hq,hkv", [(4, 1), (4, 2), (8, 8)])
    def test_gqa_ratios(self, hq, hkv):
        q, k, v = _rand_qkv(jax.random.PRNGKey(0), 2, hq, hkv, 128, 128, 64)
        out = flash_attention(q, k, v, causal=True, use_pallas=True,
                              interpret=True, block_q=64, block_k=64)
        ref = flash_attention(q, k, v, causal=True, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, k, v = _rand_qkv(jax.random.PRNGKey(1), 1, 2, 2, 128, 128, 64, dtype)
        out = flash_attention(q, k, v, causal=True, use_pallas=True,
                              interpret=True, block_q=64, block_k=64)
        ref = flash_attention(q, k, v, causal=True, use_pallas=False)
        assert out.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(out, jnp.float32), np.asarray(ref, jnp.float32), rtol=2e-2, atol=2e-2
        )

    def test_cross_attention_longer_kv(self):
        """Sq != Sk (decode/cross-attn shape), non-causal."""
        q, k, v = _rand_qkv(jax.random.PRNGKey(2), 2, 2, 2, 64, 256, 32)
        out = flash_attention(q, k, v, causal=False, use_pallas=True,
                              interpret=True, block_q=64, block_k=64)
        ref = flash_attention(q, k, v, causal=False, use_pallas=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


class TestSemantics:
    def test_causality(self):
        """Changing future keys must not change causal outputs."""
        q, k, v = _rand_qkv(jax.random.PRNGKey(3), 1, 1, 1, 128, 128, 32)
        out1 = flash_attention(q, k, v, causal=True, use_pallas=True,
                               interpret=True, block_q=64, block_k=64)
        k2 = k.at[:, :, 100:].set(99.0)
        v2 = v.at[:, :, 100:].set(-99.0)
        out2 = flash_attention(q, k2, v2, causal=True, use_pallas=True,
                               interpret=True, block_q=64, block_k=64)
        np.testing.assert_allclose(
            np.asarray(out1[:, :, :100]), np.asarray(out2[:, :, :100]), rtol=1e-5, atol=1e-5
        )

    def test_softmax_rows_convex(self):
        """Each output row is a convex combination of V rows (bounded by V range)."""
        q, k, v = _rand_qkv(jax.random.PRNGKey(4), 1, 1, 1, 64, 64, 16)
        out = flash_attention(q, k, v, causal=False, use_pallas=True,
                              interpret=True, block_q=64, block_k=64)
        assert float(out.max()) <= float(v.max()) + 1e-4
        assert float(out.min()) >= float(v.min()) - 1e-4
