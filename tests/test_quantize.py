"""Unit + property tests for the stochastic quantizer and bit packing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.quant import (
    BY_BITS,
    QuantFormat,
    fake_quantize,
    pack_codes,
    packed_len,
    quantize,
    quantize_codes,
    unpack_codes,
)

BITS = [2, 4, 8]


class TestFormats:
    @pytest.mark.parametrize("bits,levels,k", [(2, 3, 1), (4, 9, 4), (8, 129, 64)])
    def test_odd_levels(self, bits, levels, k):
        f = QuantFormat(bits)
        assert f.levels == levels
        assert f.half_steps == k
        assert f.code_min == -k and f.code_max == k

    def test_invalid_bits(self):
        with pytest.raises(ValueError):
            QuantFormat(3)

    @pytest.mark.parametrize("bits", BITS)
    def test_lemma4_bound_formula(self, bits):
        f = BY_BITS[bits]
        assert f.expected_error_bound(1.0, 4) == pytest.approx(2.0 / 2 ** (bits - 1))


class TestQuantize:
    @pytest.mark.parametrize("bits", BITS)
    def test_codes_in_range(self, bits):
        v = jax.random.normal(jax.random.PRNGKey(0), (257,))
        codes, scale = quantize_codes(v, bits, jax.random.PRNGKey(1))
        k = BY_BITS[bits].half_steps
        assert int(jnp.max(codes)) <= k and int(jnp.min(codes)) >= -k

    @pytest.mark.parametrize("bits", BITS)
    def test_elementwise_error_bounds(self, bits):
        """Stochastic rounding moves at most one full step Delta = scale/K;
        nearest rounding at most Delta/2 = scale/2^(b-1) (Lemma 4's expected
        bound is the nearest-rounding worst case)."""
        v = jax.random.normal(jax.random.PRNGKey(2), (513,))
        scale = float(jnp.max(jnp.abs(v)))
        k = BY_BITS[bits].half_steps
        d_sto = fake_quantize(v, bits, jax.random.PRNGKey(3))
        assert float(jnp.max(jnp.abs(d_sto - v))) <= scale / k + 1e-6
        d_det = fake_quantize(v, bits, key=None)
        assert float(jnp.max(jnp.abs(d_det - v))) <= scale / (2 * k) + 1e-6

    def test_unbiased(self):
        """E[Q_b(v)] = v  (statistical, 2-bit is the harshest)."""
        v = jax.random.uniform(jax.random.PRNGKey(4), (32,), minval=-1, maxval=1)
        keys = jax.random.split(jax.random.PRNGKey(5), 4000)
        mean = jax.vmap(lambda k: fake_quantize(v, 2, k))(keys).mean(0)
        # std of mean ~ scale/sqrt(n) ~ 1/63 -> 5 sigma
        np.testing.assert_allclose(np.asarray(mean), np.asarray(v), atol=0.08)

    def test_deterministic_is_nearest(self):
        v = jnp.asarray([0.0, 0.24, 0.26, -0.6, 1.0])
        d = fake_quantize(v, 4, key=None, scale=jnp.asarray(1.0))
        np.testing.assert_allclose(np.asarray(d), [0.0, 0.25, 0.25, -0.5, 1.0], atol=1e-6)

    def test_zero_exactly_representable(self):
        v = jnp.zeros((16,))
        for bits in BITS:
            d = fake_quantize(v, bits, jax.random.PRNGKey(0))
            assert float(jnp.max(jnp.abs(d))) == 0.0

    def test_complex_roundtrip(self):
        key = jax.random.PRNGKey(6)
        v = (
            jax.random.normal(key, (64,)) + 1j * jax.random.normal(jax.random.fold_in(key, 1), (64,))
        ).astype(jnp.complex64)
        q = quantize(v, 8, key)
        d = q.dequantize()
        assert d.dtype == jnp.complex64
        scale = float(q.scale)
        # stochastic rounding: at most one step (scale/K, K=64 for 8 bits)
        assert float(jnp.max(jnp.abs(jnp.real(d - v)))) <= scale / 64 + 1e-6
        assert float(jnp.max(jnp.abs(jnp.imag(d - v)))) <= scale / 64 + 1e-6

    def test_per_channel_scale(self):
        v = jnp.stack([jnp.ones(8) * 0.001, jnp.ones(8) * 100.0])
        q = quantize(v, 8, channel_axis=0)
        d = q.dequantize()
        np.testing.assert_allclose(np.asarray(d), np.asarray(v), rtol=0.02)

    def test_qtensor_is_pytree(self):
        q = quantize(jnp.ones((4,)), 4, jax.random.PRNGKey(0))
        leaves = jax.tree_util.tree_leaves(q)
        assert len(leaves) == 2
        # jaxlint: allow=JL006 -- one-shot jit: the test IS the trace-through
        out = jax.jit(lambda t: t.dequantize())(q)
        assert out.shape == (4,)


class TestPacking:
    @given(
        bits=st.sampled_from(BITS),
        n=st.integers(min_value=1, max_value=67),
        rows=st.integers(min_value=1, max_value=5),
    )
    @settings(max_examples=60, deadline=None)
    def test_pack_roundtrip(self, bits, n, rows):
        key = jax.random.PRNGKey(n * 7 + rows)
        v = jax.random.normal(key, (rows, n))
        codes, _ = quantize_codes(v, bits, key)
        packed = pack_codes(codes, bits)
        assert packed.shape == (rows, packed_len(n, bits))
        assert packed.dtype == jnp.uint8
        un = unpack_codes(packed, bits, n)
        np.testing.assert_array_equal(np.asarray(un), np.asarray(codes))

    @pytest.mark.parametrize("bits,ratio", [(2, 4), (4, 2), (8, 1)])
    def test_compression_ratio(self, bits, ratio):
        assert packed_len(128, bits) == 128 // ratio


class TestDtypeRoundTrip:
    """ISSUE-4 regression: fake_quantize must preserve the input dtype —
    complex128 measurements were silently narrowed to complex64 (dequantize
    built lax.complex from f32 parts and fake_quantize requested no dtype
    for complex inputs)."""

    @pytest.mark.parametrize("dt", ["float32", "float64", "complex64", "complex128"])
    def test_fake_quantize_preserves_dtype(self, dt):
        from jax.experimental import enable_x64

        with enable_x64():
            dtype = jnp.dtype(dt)
            v = jnp.asarray([0.5, -0.25, 1.0, 0.0], dtype)
            if jnp.issubdtype(dtype, jnp.complexfloating):
                v = v * (1.0 + 0.5j)
            out = fake_quantize(v, 8, jax.random.PRNGKey(0))
            assert out.dtype == dtype
            # values still within one quantization step
            step = float(jnp.max(jnp.abs(v))) / BY_BITS[8].half_steps
            assert float(jnp.max(jnp.abs(out - v))) <= step

    def test_complex128_explicit_f32_scale(self):
        """The narrowing path: an f32 scale must not drag the output to c64."""
        from jax.experimental import enable_x64

        with enable_x64():
            v = jnp.asarray([0.5 + 0.5j, -0.25 - 1.0j], jnp.complex128)
            q = quantize(v, 8, jax.random.PRNGKey(1), scale=jnp.float32(1.0))
            assert q.dequantize(jnp.complex128).dtype == jnp.complex128
            out = fake_quantize(v, 8, jax.random.PRNGKey(1),
                                scale=jnp.float32(1.0))
            assert out.dtype == jnp.complex128

    def test_default_x64_disabled_unchanged(self):
        v = (jax.random.normal(jax.random.PRNGKey(2), (16,))
             + 1j * jax.random.normal(jax.random.PRNGKey(3), (16,))
             ).astype(jnp.complex64)
        out = fake_quantize(v, 4, jax.random.PRNGKey(4))
        assert out.dtype == jnp.complex64
