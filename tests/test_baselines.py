"""Tests for the comparison algorithms (paper Fig. 4 / Fig. 9 baselines)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clean, cosamp, fista_l1, iht, relative_error, spectral_norm, support_recovery
from repro.sensing import (
    Station,
    dirty_beam,
    dirty_image,
    make_gaussian_problem,
    make_sky,
    measurement_matrix,
    visibilities,
)


class TestIHT:
    @pytest.mark.slow
    def test_noiseless_recovery(self):
        prob = make_gaussian_problem(128, 256, 8, snr_db=None, key=jax.random.PRNGKey(0))
        x, resid = iht(prob.phi, prob.y, prob.s, n_iters=150)
        assert float(relative_error(x, prob.x_true)) < 1e-3

    @pytest.mark.slow
    def test_residual_finite_and_shrinking(self):
        prob = make_gaussian_problem(64, 128, 4, snr_db=20.0, key=jax.random.PRNGKey(1))
        x, resid = iht(prob.phi, prob.y, prob.s, n_iters=100)
        r = np.asarray(resid)
        assert np.isfinite(r).all() and r[-1] < r[0]


class TestCoSaMP:
    @pytest.mark.slow
    def test_noiseless_recovery(self):
        prob = make_gaussian_problem(128, 256, 8, snr_db=None, key=jax.random.PRNGKey(2))
        x, _ = cosamp(prob.phi, prob.y, prob.s, n_iters=15)
        assert float(relative_error(x, prob.x_true)) < 1e-3

    def test_noisy_support(self):
        prob = make_gaussian_problem(128, 256, 8, snr_db=20.0, key=jax.random.PRNGKey(3))
        x, _ = cosamp(prob.phi, prob.y, prob.s, n_iters=15)
        assert float(support_recovery(x, prob.x_true, prob.s)) >= 0.8


class TestFISTA:
    def test_support_recovery(self):
        prob = make_gaussian_problem(128, 256, 8, snr_db=25.0, key=jax.random.PRNGKey(4))
        x, _ = fista_l1(prob.phi, prob.y, n_iters=300)
        assert float(support_recovery(x, prob.x_true, prob.s)) >= 0.8

    def test_spectral_norm_power_iteration(self):
        a = jax.random.normal(jax.random.PRNGKey(5), (40, 60))
        est = float(spectral_norm(a, iters=60))
        true = float(jnp.linalg.svd(a, compute_uv=False)[0])
        assert abs(est - true) / true < 1e-3


class TestCLEAN:
    @pytest.mark.slow
    def test_clean_reduces_residual_and_finds_sources(self):
        st = Station(n_antennas=20)
        r = 32
        phi = measurement_matrix(st, r, extent=1.5)
        key = jax.random.PRNGKey(6)
        x = make_sky(r, 5, key, min_sep=5)
        y, _ = visibilities(phi, x, 20.0, key)
        di = dirty_image(phi, y, r)
        db = dirty_beam(phi, r)
        comps, resid, peaks = clean(di, db, gain=0.2, n_iters=150)
        p = np.asarray(peaks)
        assert p[-1] < p[0]
        # the strongest CLEAN component should sit on (or next to) a true source
        ci = int(jnp.argmax(jnp.abs(comps)))
        ti = np.argwhere(np.asarray(x.reshape(r, r)) > 0)
        dist = np.min(np.max(np.abs(ti - np.array([ci // r, ci % r])), axis=1))
        assert dist <= 1
