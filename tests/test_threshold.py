"""Tests for the hard-threshold operator H_s (exact and bisection variants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import find_threshold_bisect, hard_threshold, hard_threshold_bisect, top_s_mask


class TestHardThreshold:
    def test_keeps_top_s(self):
        x = jnp.asarray([0.1, -5.0, 2.0, 0.0, -3.0])
        out = hard_threshold(x, 2)
        np.testing.assert_allclose(np.asarray(out), [0.0, -5.0, 0.0, 0.0, -3.0])

    def test_support_size(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (100,))
        for s in [1, 7, 50, 100]:
            out = hard_threshold(x, s)
            assert int(jnp.sum(jnp.abs(out) > 0)) == s

    def test_s_ge_n_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (10,))
        np.testing.assert_array_equal(np.asarray(hard_threshold(x, 10)), np.asarray(x))
        np.testing.assert_array_equal(np.asarray(hard_threshold(x, 20)), np.asarray(x))

    def test_complex_magnitude(self):
        x = jnp.asarray([1 + 1j, 0.5 + 0j, 3j, -0.1 + 0.1j], dtype=jnp.complex64)
        out = hard_threshold(x, 2)
        assert out[2] == 3j and out[0] == 1 + 1j
        assert out[1] == 0 and out[3] == 0

    def test_rejects_matrix(self):
        with pytest.raises(ValueError):
            hard_threshold(jnp.ones((2, 2)), 1)

    def test_best_s_term_approximation(self):
        """H_s(x) is the best s-term approximation in l2."""
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (50,))
        s = 5
        xs = hard_threshold(x, s)
        err = float(jnp.linalg.norm(x - xs))
        for trial in range(10):
            idx = jax.random.choice(jax.random.fold_in(key, trial), 50, (s,), replace=False)
            alt = jnp.zeros_like(x).at[idx].set(x[idx])
            assert err <= float(jnp.linalg.norm(x - alt)) + 1e-6


class TestBisect:
    @given(n=st.integers(8, 300), s_frac=st.floats(0.05, 0.9), seed=st.integers(0, 99))
    @settings(max_examples=40, deadline=None)
    def test_matches_topk_distinct(self, n, s_frac, seed):
        """With distinct magnitudes the bisection H_s equals the exact H_s."""
        s = max(1, int(n * s_frac))
        x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        a = np.asarray(hard_threshold(x, s))
        b = np.asarray(hard_threshold_bisect(x, s))
        np.testing.assert_allclose(a, b, atol=1e-6)

    def test_threshold_value(self):
        x = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])
        t = float(find_threshold_bisect(jnp.abs(x), 2))
        assert 3.0 <= t < 4.0

    def test_ties_keep_at_most_s(self):
        x = jnp.asarray([1.0, 1.0, 1.0, 1.0, 2.0])
        out = hard_threshold_bisect(x, 2)
        assert int(jnp.sum(jnp.abs(out) > 0)) <= 2

    def test_top_s_mask(self):
        x = jnp.asarray([3.0, -1.0, 2.0])
        m = top_s_mask(x, 2)
        np.testing.assert_array_equal(np.asarray(m), [True, False, True])


class TestTieDegeneracy:
    """ISSUE-4 regression: tied magnitudes at the threshold must not collapse
    the support to empty (the flat-phantom degeneracy that silently re-enters
    the solver's init branch)."""

    def test_all_equal_keeps_exactly_s_by_index(self):
        x = jnp.ones((16,))
        out = hard_threshold_bisect(x, 5)
        np.testing.assert_array_equal(np.asarray(jnp.abs(out) > 0),
                                      [True] * 5 + [False] * 11)

    def test_piecewise_constant_phantom_profile(self):
        # two plateaus, tie at the threshold inside the top plateau
        x = jnp.concatenate([jnp.full((8,), 2.0), jnp.full((8,), 1.0)])
        out = hard_threshold_bisect(x, 4)
        assert int(jnp.sum(jnp.abs(out) > 0)) == 4
        assert bool(jnp.all(out[8:] == 0))          # only top-plateau entries
        np.testing.assert_array_equal(np.asarray(out[:4]), [2.0] * 4)

    def test_zeros_never_enter_support(self):
        x = jnp.zeros((16,)).at[3].set(1.0)
        out = hard_threshold_bisect(x, 5)
        assert int(jnp.sum(jnp.abs(out) > 0)) == 1

    @given(n=st.integers(8, 200), s_frac=st.floats(0.05, 0.9),
           n_levels=st.integers(1, 4), seed=st.integers(0, 99))
    @settings(max_examples=40, deadline=None)
    def test_tied_magnitudes_match_hard_threshold(self, n, s_frac, n_levels, seed):
        """Property (vs exact H_s): on arbitrarily tied inputs the bisection
        keeps the SAME multiset of magnitudes as top-k, with support size
        min(s, nnz)."""
        s = max(1, int(n * s_frac))
        key = jax.random.PRNGKey(seed)
        levels = jnp.arange(n_levels, dtype=jnp.float32)  # includes exact 0
        x = levels[jax.random.randint(key, (n,), 0, n_levels)]
        signs = jnp.where(jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5,
                                               (n,)), 1.0, -1.0)
        x = x * signs
        a = np.abs(np.asarray(hard_threshold(x, s)))
        b = np.abs(np.asarray(hard_threshold_bisect(x, s)))
        assert (b > 0).sum() == (a > 0).sum() == min(s, int((np.abs(np.asarray(x)) > 0).sum()))
        np.testing.assert_allclose(np.sort(b)[::-1], np.sort(a)[::-1], atol=1e-6)

    def test_hsthresh_flat_input_keeps_s(self):
        """The kernel path of the same degeneracy (histogram select)."""
        from repro.kernels.hsthresh.ops import hsthresh

        x = jnp.ones((64,))
        for use_pallas in (False, True):
            out = hsthresh(x, 7, use_pallas=use_pallas, interpret=use_pallas)
            assert int(jnp.sum(jnp.abs(out) > 0)) == 7
