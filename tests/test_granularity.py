"""Group-scaled quantization end to end: Granularity plumbing, Lemma-4
per-block error bounds, frozen per-tensor fixtures, the group-scaled qmm
kernels, packed-operator granularity, qniht threading, and per-band k-space
observation quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_shim import given, settings, st

from repro.core import qniht, relative_error
from repro.core.operators import PackedStreamingOperator
from repro.kernels.qmm.ops import pack_operator, pack_weights, qmm
from repro.kernels.qmm.ref import qmm_group_ref
from repro.quant import (
    Granularity,
    as_granularity,
    expand_block_scale,
    fake_quantize,
    per_block,
    quantize,
    quantize_codes,
    validate_group_packing,
)
from repro.sensing import (
    kspace_band_scales,
    kspace_radial_bands,
    make_gaussian_problem,
    make_mri_problem,
    quantize_observations,
)

BITS = [2, 4, 8]

# ---------------------------------------------------------------------------
# Frozen fixture: the pre-refactor per-tensor quantizer output for
# jax.random.normal(PRNGKey(42), (24,)). The refactored default path must
# reproduce these codes BIT-IDENTICALLY (nearest and stochastic rounding).
# ---------------------------------------------------------------------------
_FIXTURE_KEY = 42
_FIXTURE_SCALE = 2.130046844482422
_FROZEN_NEAREST = {
    2: [0, 0, 1, -1, -1, 0, 0, 0, 0, 0, 1, 1, 0, 0, 0, 1, -1, 0, 0, 0, 0, -1, 1, 0],
    4: [1, 2, 4, -3, -3, 0, -2, 1, 0, 2, 2, 2, -1, 0, 2, 3, -3, 1, -1, 0, -1, -2, 2, -1],
    8: [18, 32, 64, -42, -55, -2, -28, 15, 8, 26, 33, 40, -13, -3, 26, 53, -45, 19,
        -13, -3, -8, -36, 32, -23],
}
_FROZEN_STOCHASTIC = {  # key = PRNGKey(7)
    2: [0, 0, 1, 0, -1, 0, 0, 0, 0, 0, 1, 1, 0, 0, 0, 1, -1, 0, 0, 0, 0, -1, 0, 0],
    4: [1, 2, 4, -2, -3, 0, -2, 1, 1, 1, 2, 3, -1, 0, 1, 3, -3, 1, -1, 0, -1, -2, 2, -1],
    8: [17, 32, 64, -41, -55, -2, -28, 14, 8, 26, 33, 40, -13, -3, 26, 53, -45, 19,
        -13, -2, -9, -36, 32, -22],
}


class TestFrozenPerTensorFixture:
    @pytest.mark.parametrize("bits", BITS)
    def test_nearest_codes_bit_identical(self, bits):
        v = jax.random.normal(jax.random.PRNGKey(_FIXTURE_KEY), (24,), jnp.float32)
        codes, scale = quantize_codes(v, bits, key=None)
        assert [int(c) for c in codes] == _FROZEN_NEAREST[bits]
        assert float(scale) == _FIXTURE_SCALE

    @pytest.mark.parametrize("bits", BITS)
    def test_stochastic_codes_bit_identical(self, bits):
        v = jax.random.normal(jax.random.PRNGKey(_FIXTURE_KEY), (24,), jnp.float32)
        codes, scale = quantize_codes(v, bits, key=jax.random.PRNGKey(7))
        assert [int(c) for c in codes] == _FROZEN_STOCHASTIC[bits]
        assert float(scale) == _FIXTURE_SCALE

    @pytest.mark.parametrize("bits", BITS)
    def test_explicit_per_tensor_matches_default(self, bits):
        v = jax.random.normal(jax.random.PRNGKey(_FIXTURE_KEY), (24,), jnp.float32)
        codes, _ = quantize_codes(v, bits, key=None, granularity="per_tensor")
        assert [int(c) for c in codes] == _FROZEN_NEAREST[bits]


class TestGranularitySpelling:
    def test_parse_forms(self):
        assert as_granularity(None).is_per_tensor
        assert as_granularity("per_row") == Granularity("per_channel")
        assert as_granularity("per_block:64") == per_block(64)
        assert as_granularity("per_block", 32) == per_block(32)
        assert str(per_block(16)) == "per_block:16"

    def test_invalid(self):
        with pytest.raises(ValueError):
            as_granularity("per_banana")
        with pytest.raises(ValueError):
            Granularity("per_block")          # missing group_size
        with pytest.raises(ValueError):
            Granularity("per_tensor", 8)      # group_size without per_block
        with pytest.raises(ValueError):
            as_granularity("per_channel", 8)

    def test_group_packing_alignment(self):
        validate_group_packing(8, 2)
        with pytest.raises(ValueError):
            validate_group_packing(6, 2)      # 4 values/byte at 2 bits

    def test_scale_accounting(self):
        g = per_block(16)
        assert g.n_groups(100) == 7
        assert g.scale_nbytes((4, 100)) == 4 * 4 * 7
        assert as_granularity("per_tensor").scale_nbytes((4, 100)) == 4


class TestLemma4PerBlockBound:
    @given(
        bits=st.sampled_from(BITS),
        n=st.integers(8, 200),
        group=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 500),
    )
    @settings(max_examples=40, deadline=None)
    def test_per_element_bound_per_block(self, bits, n, group, seed):
        """Lemma 4's per-element bound with the LOCAL scale: nearest rounding
        moves each element at most scale_blk / 2^(b-1); within every block the
        scale is that block's own max-abs, not the global one."""
        key = jax.random.PRNGKey(seed)
        # strongly non-uniform dynamic range across blocks (the k-space shape)
        v = (jax.random.normal(key, (n,), jnp.float32)
             * jnp.logspace(-3, 2, n, dtype=jnp.float32))
        q = quantize(v, bits, granularity=per_block(group))
        bound = expand_block_scale(q.scale, group, n) / 2 ** (bits - 1)
        err = jnp.abs(q.dequantize() - v)
        assert float(jnp.max(err - bound)) <= 1e-5 * float(jnp.max(bound))

    @given(bits=st.sampled_from(BITS), seed=st.integers(0, 500))
    @settings(max_examples=20, deadline=None)
    def test_per_channel_bound(self, bits, seed):
        key = jax.random.PRNGKey(seed)
        v = jax.random.normal(key, (6, 64), jnp.float32) * jnp.logspace(
            -2, 2, 6, dtype=jnp.float32)[:, None]
        q = quantize(v, bits, granularity="per_channel")
        assert q.scale.shape == (6, 1)
        err = jnp.abs(q.dequantize() - v)
        bound = q.scale / 2 ** (bits - 1)
        assert float(jnp.max(err - bound)) <= 1e-6

    def test_blockwise_preserves_small_coefficients(self):
        # block-structured dynamic range (each 32-group has its own magnitude,
        # like k-space bands). The single per-tensor scale sets its rounding
        # step from the dominant block, flushing small-magnitude blocks to
        # zero (100% error there); local scales keep them representable.
        mags = jnp.repeat(jnp.logspace(-3, 2, 16, dtype=jnp.float32), 32)
        v = jax.random.normal(jax.random.PRNGKey(0), (512,), jnp.float32) * mags
        small = mags < 1e-1 * float(jnp.max(jnp.abs(v)))
        vs = v[small]
        e_tensor = float(jnp.linalg.norm(fake_quantize(v, 4)[small] - vs))
        e_block = float(jnp.linalg.norm(
            fake_quantize(v, 4, granularity=per_block(32))[small] - vs))
        assert e_tensor > 0.6 * float(jnp.linalg.norm(vs))   # mostly flushed
        assert e_block < 0.25 * float(jnp.linalg.norm(vs))   # locally resolved

    def test_ragged_last_block(self):
        v = jnp.arange(1.0, 11.0)      # n=10, g=4 -> blocks 4,4,2
        q = quantize(v, 8, granularity=per_block(4))
        assert q.scale.shape == (3,)
        np.testing.assert_allclose(np.asarray(q.scale), [4.0, 8.0, 10.0])
        np.testing.assert_allclose(np.asarray(q.dequantize()), np.asarray(v),
                                   rtol=0.02)


@pytest.mark.slow
class TestGroupScaledQmm:
    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.parametrize("group", [8, 32])
    def test_kernel_matches_ref_real(self, bits, group):
        key = jax.random.PRNGKey(1)
        m, k, n = 8, 200, 48
        x = jax.random.normal(key, (m, k), jnp.float32)
        w = (jax.random.normal(jax.random.fold_in(key, 1), (n, k), jnp.float32)
             * jnp.logspace(-2, 2, k, dtype=jnp.float32))
        pw = pack_weights(w, bits, jax.random.fold_in(key, 2),
                          granularity=per_block(group))
        assert pw.scale.shape == (n, (k + group - 1) // group)
        ref = qmm_group_ref(x, pw.packed, pw.scale, bits, k, group)
        out = qmm(x, pw, use_pallas=True, interpret=True)
        rel = float(jnp.linalg.norm(out - ref) / (jnp.linalg.norm(ref) + 1e-30))
        assert rel <= 1e-5

    @pytest.mark.parametrize("bits", BITS)
    def test_semantics_match_fake_quantize(self, bits):
        """qmm(per_block) == x @ Q_blockwise(w)^T — the framework quantizer."""
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (4, 96), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (16, 96), jnp.float32)
        kq = jax.random.fold_in(key, 2)
        pw = pack_weights(w, bits, kq, granularity=per_block(16))
        out = qmm(x, pw, use_pallas=False)
        w_deq = fake_quantize(w, bits, kq, granularity=per_block(16))
        np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w_deq.T),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("bits", BITS)
    def test_complex_operator_matvec_rmatvec(self, bits):
        key = jax.random.PRNGKey(3)
        phi = (jax.random.normal(key, (24, 48))
               + 1j * jax.random.normal(jax.random.fold_in(key, 1), (24, 48))
               ).astype(jnp.complex64)
        op = PackedStreamingOperator.pack(phi, bits, jax.random.fold_in(key, 2),
                                          granularity=per_block(8))
        x = jax.random.normal(jax.random.fold_in(key, 3), (48,), jnp.float32)
        r = (jax.random.normal(jax.random.fold_in(key, 4), (24,))
             + 1j * jax.random.normal(jax.random.fold_in(key, 5), (24,))
             ).astype(jnp.complex64)
        # kernel (interpret) vs pure-jnp ref, both orientations
        a = PackedStreamingOperator(op.packed, use_pallas=True, interpret=True)
        b = PackedStreamingOperator(op.packed, use_pallas=False)
        np.testing.assert_allclose(np.asarray(a.mv(x)), np.asarray(b.mv(x)),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(a.rmv(r)), np.asarray(b.rmv(r)),
                                   rtol=1e-5, atol=1e-5)

    def test_group_scale_bytes_accounting(self):
        phi = jax.random.normal(jax.random.PRNGKey(4), (64, 128), jnp.float32)
        op = PackedStreamingOperator.pack(phi, 4, granularity=per_block(32))
        # fwd (64,128): 64*4 groups; codes bytes unchanged vs per-tensor
        assert op.scale_nbytes == 64 * (128 // 32) * 4
        op_pt = PackedStreamingOperator.pack(phi, 4)
        assert op.nbytes == op_pt.nbytes


class TestPackOperatorSharedConflict:
    """Satellite: ONE clear error for shared=True with per-orientation scales."""

    def test_shared_with_per_channel_bool(self):
        phi = jax.random.normal(jax.random.PRNGKey(5), (16, 24), jnp.float32)
        with pytest.raises(ValueError, match="shared=False.*per_tensor"):
            pack_operator(phi, 4, shared=True, per_channel=True)

    def test_shared_with_group_granularity(self):
        phi = jax.random.normal(jax.random.PRNGKey(5), (16, 24), jnp.float32)
        with pytest.raises(ValueError, match="shared=False.*per_tensor"):
            pack_operator(phi, 4, shared=True, granularity=per_block(8))

    def test_shared_per_tensor_still_fine(self):
        phi = jax.random.normal(jax.random.PRNGKey(5), (16, 24), jnp.float32)
        op = pack_operator(phi, 4, shared=True, granularity="per_tensor")
        assert op.fwd_re.granularity.is_per_tensor


class TestQnihtGranularity:
    @pytest.mark.slow
    def test_per_tensor_bit_identical_to_default(self):
        key = jax.random.PRNGKey(10)
        prob = make_gaussian_problem(64, 128, 6, snr_db=25.0, key=key)
        kw = dict(bits_phi=4, bits_y=8, key=key, requantize="fixed",
                  backend="packed")
        r_def = qniht(prob.phi, prob.y, prob.s, 20, **kw)
        r_pt = qniht(prob.phi, prob.y, prob.s, 20,
                     scale_granularity="per_tensor", **kw)
        assert float(jnp.max(jnp.abs(r_def.x - r_pt.x))) == 0.0

    @pytest.mark.slow
    def test_group_scaled_runs_and_recovers(self):
        key = jax.random.PRNGKey(11)
        prob = make_gaussian_problem(64, 128, 6, snr_db=25.0, key=key)
        kw = dict(bits_phi=4, bits_y=8, key=key, requantize="fixed",
                  backend="packed")
        r_pt = qniht(prob.phi, prob.y, prob.s, 30, **kw)
        r_gb = qniht(prob.phi, prob.y, prob.s, 30,
                     scale_granularity="per_block", group_size=16, **kw)
        e_pt = float(relative_error(r_pt.x, prob.x_true))
        e_gb = float(relative_error(r_gb.x, prob.x_true))
        assert np.isfinite(e_gb)
        assert e_gb <= e_pt + 0.05   # finer scales should not hurt recovery

    def test_granularity_requires_packed_backend(self):
        key = jax.random.PRNGKey(12)
        prob = make_gaussian_problem(32, 64, 3, key=key)
        with pytest.raises(ValueError, match="packed"):
            qniht(prob.phi, prob.y, 3, 5, bits_phi=4, bits_y=8, key=key,
                  scale_granularity="per_block", group_size=16)


class TestPerBandKspace:
    def test_band_geometry(self):
        prob = make_mri_problem(32, 40, 0.4, jax.random.PRNGKey(0))
        bands = kspace_radial_bands(prob.op, n_bands=8)
        assert bands.shape == (prob.op.shape[0],)
        assert int(bands.min()) >= 0 and int(bands.max()) <= 7
        # DC (flat index 0 in the unshifted convention) sits in band 0
        dc_pos = int(jnp.argmax(prob.op.indices == 0))
        assert prob.op.indices[dc_pos] == 0
        assert int(bands[dc_pos]) == 0

    def test_band_scales_bound_samples(self):
        prob = make_mri_problem(32, 40, 0.4, jax.random.PRNGKey(1))
        bands = kspace_radial_bands(prob.op, n_bands=8)
        scales = kspace_band_scales(prob.y, bands, 8)
        mag = jnp.maximum(jnp.abs(prob.y.real), jnp.abs(prob.y.imag))
        assert float(jnp.max(mag - scales[bands])) <= 1e-6

    @pytest.mark.parametrize("bits", [4, 2])
    def test_per_band_quantization_noise_much_smaller(self, bits):
        """The whole point: per-band ŷ is far closer to y than per-tensor ŷ
        because the shared c_y step is set by the huge DC coefficients."""
        prob = make_mri_problem(64, 120, 0.35, jax.random.PRNGKey(2))
        key = jax.random.PRNGKey(3)
        y_pt = quantize_observations(prob.y, bits, key)
        y_pb = quantize_observations(prob.y, bits, key, granularity="per_band",
                                     op=prob.op, n_bands=16)
        e_pt = float(jnp.linalg.norm(y_pt - prob.y))
        e_pb = float(jnp.linalg.norm(y_pb - prob.y))
        assert e_pb < 0.5 * e_pt

    def test_per_band_error_bound_per_sample(self):
        prob = make_mri_problem(32, 40, 0.4, jax.random.PRNGKey(4))
        bits, nb = 4, 8
        yq = quantize_observations(prob.y, bits, jax.random.PRNGKey(5),
                                   granularity="per_band", op=prob.op, n_bands=nb)
        bands = kspace_radial_bands(prob.op, n_bands=nb)
        step = kspace_band_scales(prob.y, bands, nb)[bands] / 2 ** (bits - 2)
        # stochastic rounding moves each component at most one full step
        assert float(jnp.max(jnp.abs(yq.real - prob.y.real) - step)) <= 1e-6
        assert float(jnp.max(jnp.abs(yq.imag - prob.y.imag) - step)) <= 1e-6

    def test_batched_rows_match_singles(self):
        prob = make_mri_problem(32, 40, 0.4, jax.random.PRNGKey(6))
        key = jax.random.PRNGKey(7)
        Y = jnp.stack([prob.y, 3.0 * prob.y])
        Yq = quantize_observations(Y, 4, key, granularity="per_band",
                                   op=prob.op, n_bands=8)
        for b, row in enumerate([prob.y, 3.0 * prob.y]):
            single = quantize_observations(row, 4, key, granularity="per_band",
                                           op=prob.op, n_bands=8)
            np.testing.assert_allclose(np.asarray(Yq[b]), np.asarray(single),
                                       rtol=1e-6, atol=1e-6)

    def test_per_tensor_matches_fake_quantize(self):
        prob = make_mri_problem(32, 40, 0.4, jax.random.PRNGKey(8))
        key = jax.random.PRNGKey(9)
        a = quantize_observations(prob.y, 8, key)
        b = fake_quantize(prob.y, 8, key)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_rejects_unknown_granularity_and_missing_op(self):
        prob = make_mri_problem(32, 40, 0.4, jax.random.PRNGKey(10))
        with pytest.raises(ValueError, match="per_band"):
            quantize_observations(prob.y, 4, jax.random.PRNGKey(0),
                                  granularity="per_pixel")
        with pytest.raises(ValueError, match="op"):
            quantize_observations(prob.y, 4, jax.random.PRNGKey(0),
                                  granularity="per_band")
