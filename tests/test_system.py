"""End-to-end behaviour tests for the paper's system.

The paper's pipeline, run whole: simulate the instrument → quantize ALL input
data (Φ to 2 bits, y to 8 bits) → recover → validate against the full-precision
run and the theory-side quantities. Plus the framework-level integration the
paper's insight feeds (quantized serving bytes, compressed-gradient training).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # end-to-end pipeline runs, ~30s total

from repro.core import (
    eps_q,
    niht,
    qniht,
    relative_error,
    rics_sampled,
    source_recovery,
    support_recovery,
)
from repro.quant import PAPER_2_8
from repro.sensing import Station, make_sky, measurement_matrix, visibilities


class TestPaperPipelineEndToEnd:
    """The full QNIHT pipeline at CI scale (paper §4 scaled down)."""

    def setup_method(self):
        self.key = jax.random.PRNGKey(302)
        self.r, self.s = 32, 8
        st = Station(n_antennas=30, seed=302)
        self.phi = measurement_matrix(st, self.r, extent=1.5)
        self.x = make_sky(self.r, self.s, self.key, min_sep=4)
        self.y, _ = visibilities(self.phi, self.x, 0.0, self.key)  # 0 dB

    @pytest.mark.slow
    def test_low_precision_recovery_matches_full(self):
        full = niht(self.phi, self.y, self.s, 40, real_signal=True, nonneg=True)
        low = qniht(self.phi, self.y, self.s, 40,
                    bits_phi=PAPER_2_8.phi_bits, bits_y=PAPER_2_8.y_bits,
                    key=self.key, real_signal=True, nonneg=True)
        e_full = float(relative_error(full.x, self.x))
        e_low = float(relative_error(low.x, self.x))
        assert float(support_recovery(low.x, self.x, self.s)) >= 0.85
        assert e_low <= e_full + 0.15    # "negligible loss" at 1/16th the bytes
        img = jnp.real(low.x).reshape(self.r, self.r)
        assert float(source_recovery(img, self.x.reshape(self.r, self.r),
                                     self.s, 1)) >= 0.85

    def test_quantization_error_term_small_vs_signal(self):
        """Corollary-1 mechanics: ε_q with the measured β̂_2s is bounded at the
        signal's order for this instrument (why 2 bits suffice here)."""
        _, beta_hat = rics_sampled(self.phi, 2 * self.s, 16, self.key)
        xs_norm = float(jnp.linalg.norm(self.x))
        e_q = eps_q(self.phi.shape[0], float(beta_hat), xs_norm, 2, 8)
        assert e_q < 2.0 * xs_norm

    def test_monotone_in_bits(self):
        """8&8 ≈ full precision (quantization error vanishes with bits)."""
        e8 = float(relative_error(
            qniht(self.phi, self.y, self.s, 40, bits_phi=8, bits_y=8,
                  key=self.key, real_signal=True, nonneg=True).x, self.x))
        full = float(relative_error(
            niht(self.phi, self.y, self.s, 40, real_signal=True, nonneg=True).x,
            self.x))
        assert abs(e8 - full) < 0.05


class TestFrameworkIntegration:
    def test_serving_bytes_law(self):
        """Weight quantization shrinks the streamed serving bytes (the paper's
        bandwidth law, LM side)."""
        from repro.configs import get_smoke_config
        from repro.models import init_params, param_bytes, quantize_params

        cfg = get_smoke_config("qwen3_moe_30b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        b32 = param_bytes(params)
        b4 = param_bytes(quantize_params(params, 4))
        b2 = param_bytes(quantize_params(params, 2))
        assert b4 < 0.45 * b32
        assert b2 < b4

    @pytest.mark.slow
    def test_compressed_gradient_training_converges(self):
        """Unbiased Q8 gradients do not break optimization (QSGD lineage)."""
        from repro.configs import get_smoke_config
        from repro.data import SyntheticStream
        from repro.optim import adamw
        from repro.quant.policy import QuantPolicy
        from repro.train import init_state, make_train_step

        cfg = get_smoke_config("minitron_4b")
        opt = adamw(3e-3)
        step = jax.jit(make_train_step(cfg, opt, policy=QuantPolicy(grad_bits=8)))
        state = init_state(cfg, opt, jax.random.PRNGKey(0))
        stream = SyntheticStream(0, 8, 32, cfg.vocab_size)
        losses = []
        for i in range(20):
            b = stream.at_step(i)
            b["memory"] = None
            state, m = step(state, b)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.2
        assert all(np.isfinite(losses))
