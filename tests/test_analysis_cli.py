"""Analysis CLI contract tests: exit codes, output formats, and the
``--update-baseline`` round-trip (update → clean run → stale rejection).

These drive ``repro.analysis.cli.main`` in-process. The AST-only paths stay
jax-free (millisecond runs); the two jaxpr-tier tests use a tiny/empty
registry file so they pay jax import but no real tracing.
"""
import json
import os
import textwrap

import pytest

from repro.analysis.cli import main as cli_main
from repro.analysis.engine import BASELINE_NAME

BAD_SRC = textwrap.dedent("""\
    import jax.numpy as jnp

    def demote(x):
        return x.astype(jnp.complex64)   # JL001: literal narrowing cast
""")


@pytest.fixture()
def tmp_repo(tmp_path):
    """A minimal repo root: pyproject.toml marker + src/ with one finding."""
    (tmp_path / "pyproject.toml").write_text("[project]\nname='t'\n")
    src = tmp_path / "src"
    src.mkdir()
    (src / "m.py").write_text(BAD_SRC)
    return tmp_path


def _run(tmp_repo, *argv):
    return cli_main(["--root", str(tmp_repo), *argv])


# ---------------------------------------------------------------- exit codes


def test_findings_exit_1(tmp_repo):
    assert _run(tmp_repo, "--baseline", "none") == 1


def test_clean_repo_exits_0(tmp_repo):
    (tmp_repo / "src" / "m.py").write_text("x = 1\n")
    assert _run(tmp_repo, "--baseline", "none") == 0


def test_unknown_rule_id_is_a_usage_error(tmp_repo):
    with pytest.raises(SystemExit) as e:
        _run(tmp_repo, "--rules", "JL999")
    assert e.value.code == 2


def test_rule_filter_crossing_tiers_is_a_usage_error(tmp_repo):
    # a JX-only filter with --tier ast selects nothing runnable
    with pytest.raises(SystemExit) as e:
        _run(tmp_repo, "--tier", "ast", "--rules", "JX103")
    assert e.value.code == 2


def test_rule_filter_limits_findings(tmp_repo):
    # JL002 never fires on the JL001 fixture source
    assert _run(tmp_repo, "--baseline", "none", "--rules", "JL002") == 0
    assert _run(tmp_repo, "--baseline", "none", "--rules", "JL001,JL002") == 1


# ------------------------------------------------------------------ formats


def test_github_format_emits_error_annotations(tmp_repo, capsys):
    assert _run(tmp_repo, "--baseline", "none", "--format", "github") == 1
    out = capsys.readouterr().out
    assert "::error file=src/m.py,line=4,title=JL001::" in out


def test_json_format_structure(tmp_repo, capsys):
    _run(tmp_repo, "--baseline", "none", "--format", "json")
    data = json.loads(capsys.readouterr().out)
    assert data["tiers"] == ["jaxlint"]
    assert data["findings"] and data["findings"][0]["rule"] == "JL001"
    assert data["stale_baseline_entries"] == []


# --------------------------------------------------- baseline round-trip


def test_update_baseline_round_trip_then_stale_rejection(tmp_repo, capsys):
    bl = tmp_repo / BASELINE_NAME
    # 1. update: findings land in the baseline with a placeholder reason
    assert _run(tmp_repo, "--update-baseline") == 0
    entries = json.loads(bl.read_text())["entries"]
    assert len(entries) == 1 and entries[0]["rule"] == "JL001"
    assert "TODO" in entries[0]["reason"]
    # 2. clean run: the same finding is now suppressed
    assert _run(tmp_repo) == 0
    # 3. justified reasons survive a re-update
    entries[0]["reason"] = "vetted: fixture demotion is the point here"
    bl.write_text(json.dumps({"version": 1, "entries": entries}, indent=2))
    assert _run(tmp_repo, "--update-baseline") == 0
    kept = json.loads(bl.read_text())["entries"]
    assert kept[0]["reason"].startswith("vetted:")
    # 4. the flagged code changes -> the entry is stale -> blocking rejection
    (tmp_repo / "src" / "m.py").write_text("x = 1\n")
    capsys.readouterr()
    assert _run(tmp_repo) == 1
    assert "stale baseline entry" in capsys.readouterr().out


def test_update_baseline_preserves_other_tiers_entries(tmp_repo):
    bl = tmp_repo / BASELINE_NAME
    jx_entry = {"rule": "JX103", "path": "src/other.py",
                "snippet": "while_loop(...)",
                "reason": "vetted: schema uniformity"}
    bl.write_text(json.dumps({"version": 1, "entries": [jx_entry]}, indent=2))
    # an AST-tier update must not drop the jaxpr tier's vetted entries
    assert _run(tmp_repo, "--update-baseline", "--tier", "ast") == 0
    entries = json.loads(bl.read_text())["entries"]
    rules = sorted(e["rule"] for e in entries)
    assert rules == ["JL001", "JX103"]
    assert [e for e in entries if e["rule"] == "JX103"][0] == jx_entry


def test_stale_check_skipped_for_explicit_paths(tmp_repo):
    bl = tmp_repo / BASELINE_NAME
    bl.write_text(json.dumps({"version": 1, "entries": [
        {"rule": "JL001", "path": "src/gone.py", "snippet": "nope",
         "reason": "vetted: entry for a file being linted elsewhere"}]},
        indent=2))
    (tmp_repo / "src" / "m.py").write_text("x = 1\n")
    # naming a path narrows the run — staleness is only judged on full runs
    assert _run(tmp_repo, "src/m.py") == 0
    assert _run(tmp_repo) == 1


# ------------------------------------------------------------- jaxpr tier


def test_jaxpr_budget_blows_on_tiny_budget(tmp_repo, capsys):
    reg = tmp_repo / "empty_registry.py"
    reg.write_text("ENTRIES = []\n")
    assert _run(tmp_repo, "--tier", "jaxpr", "--registry", str(reg),
                "--baseline", "none") == 0
    assert _run(tmp_repo, "--tier", "jaxpr", "--registry", str(reg),
                "--baseline", "none", "--budget", "0.0000001") == 1
    assert "BUDGET EXCEEDED" in capsys.readouterr().out


def test_list_entries_prints_registry(capsys):
    assert cli_main(["--list-entries"]) == 0
    out = capsys.readouterr().out
    assert "qniht.packed.per_tensor" in out
    assert "batch_server.chunk_fn" in out
