"""Runtime sanitizer tier: NaN tripwires + compile-count regression tests.

The compile tests pin the serving-path retrace contract from PRs 5-6: a
BatchServer compiles its sharded solve ONCE and then serves same-shape
chunks from cache, and repeated same-shape ``qniht`` calls never retrace.
Every test uses shapes unique within the suite (odd dims) so a cache
already warmed by another test cannot deflate — or inflate — the counts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitize import CompileCounter, sanitize
from repro.core.niht import qniht
from repro.parallel.batch import BatchServer


def _problem(m, n, s, b, seed):
    rng = np.random.default_rng(seed)
    phi = jnp.asarray(rng.standard_normal((m, n), dtype=np.float32) / np.sqrt(m))
    X = np.zeros((b, n), dtype=np.float32)
    for i in range(b):
        X[i, rng.choice(n, size=s, replace=False)] = rng.standard_normal(s)
    Y = jnp.asarray(X, dtype=phi.dtype) @ phi.T
    return phi, Y


# ---------------------------------------------------------------- counter


def test_compile_counter_counts_fresh_and_cached():
    @jax.jit
    def f(v):
        return jnp.tanh(v) * 3.0

    x = jnp.ones((37,), jnp.float32)  # unique shape: forces a fresh compile
    with CompileCounter() as cc:
        f(x).block_until_ready()
        assert cc.compiles == 1
        cc.mark_warm()
        f(x).block_until_ready()
        f(jnp.zeros((37,), jnp.float32)).block_until_ready()  # same shape: cached
    assert cc.compiles == 1
    assert cc.compiles_since_warm == 0
    assert cc.compile_seconds > 0.0
    assert "compiles_after_warmup=0" in cc.summary()


def test_compile_counter_detects_retrace():
    def g(v):
        return v + 1.0

    with CompileCounter() as cc:
        cc.mark_warm()
        # fresh wrapper per call: the exact bug JL006 lints for
        jax.jit(g)(jnp.ones((41,), jnp.float32)).block_until_ready()  # jaxlint: allow=JL006 -- the test IS the retrace bug
        jax.jit(g)(jnp.ones((41,), jnp.float32)).block_until_ready()  # jaxlint: allow=JL006 -- the test IS the retrace bug
    assert cc.compiles_since_warm == 2


# ---------------------------------------------------------------- sanitize


def test_sanitize_trips_on_nan():
    with sanitize():
        with pytest.raises(FloatingPointError):
            jnp.sqrt(jnp.asarray(-1.0)).block_until_ready()


def test_sanitize_trips_on_inf():
    with sanitize():
        with pytest.raises(FloatingPointError):
            (jnp.asarray(1.0, jnp.float32) / jnp.asarray(0.0, jnp.float32)
             ).block_until_ready()


def test_sanitize_restores_flags():
    before = (jax.config.jax_debug_nans, jax.config.jax_debug_infs)
    with sanitize():
        assert jax.config.jax_debug_nans and jax.config.jax_debug_infs
    assert (jax.config.jax_debug_nans, jax.config.jax_debug_infs) == before
    # restoration must also survive the tripwire firing
    try:
        with sanitize():
            jnp.log(jnp.asarray(0.0)).block_until_ready()
    except FloatingPointError:
        pass
    assert (jax.config.jax_debug_nans, jax.config.jax_debug_infs) == before


def test_sanitize_allows_intentional_nan_transfer():
    # the niht/batch placeholder idiom: NaN built host-side and transferred
    # is a device_put, not an op — must NOT trip the tripwire
    with sanitize():
        buf = jnp.asarray(np.full((5,), np.nan, np.float32))
        assert bool(jnp.all(jnp.isnan(buf)))


def test_sanitize_threads_counter():
    with sanitize(counter=CompileCounter()) as cc:
        assert isinstance(cc, CompileCounter)
        jax.jit(lambda v: v * 2.0)(jnp.ones((43,), jnp.float32))  # jaxlint: allow=JL006 -- one-shot jit, the compile is the point
    assert cc.compiles >= 1


# ------------------------------------------------------- serving contract


def test_batchserver_compiles_once_for_three_same_shape_chunks():
    """Acceptance criterion: 3 same-shape chunks through a BatchServer ->
    exactly 1 backend compile (the sharded solve), chunks 2-3 pure cache."""
    phi, Y = _problem(m=33, n=65, s=3, b=6, seed=7)
    srv = BatchServer(phi, s=3, n_iters=12, n_devices=1, with_trace=True)
    chunks = [Y[:2], Y[2:4], Y[4:6]]
    with sanitize(counter=CompileCounter()) as cc:
        res = srv.submit(chunks[0])
        jax.block_until_ready(res.x)
        assert cc.compiles == 1, (
            f"expected exactly 1 compile for the first chunk, saw {cc.compiles}")
        cc.mark_warm()
        for chunk in chunks[1:]:
            jax.block_until_ready(srv.submit(chunk).x)
    assert cc.compiles == 1, f"retrace on same-shape chunks: {cc.summary()}"
    assert cc.compiles_since_warm == 0
    assert srv.n_chunks == 3


@pytest.mark.parametrize("backend", ["dense", "packed"])
def test_qniht_no_retrace_on_repeated_same_shape_calls(backend):
    # unique shape per backend so neither call can hit another test's cache
    m, n = (35, 67) if backend == "dense" else (39, 69)
    phi, Y = _problem(m=m, n=n, s=3, b=1, seed=11)
    kw = dict(s=3, n_iters=10, bits_phi=8, bits_y=8, backend=backend,
              requantize="fixed", key=jax.random.PRNGKey(0), with_trace=False)
    y2 = jax.block_until_ready(Y[0] * 0.5)  # built outside the counted window
    jax.block_until_ready(qniht(phi, Y[0], **kw).x)  # warm the cache
    with CompileCounter() as cc:
        jax.block_until_ready(qniht(phi, Y[0], **kw).x)
        jax.block_until_ready(qniht(phi, y2, **kw).x)
    assert cc.compiles == 0, f"{backend} qniht retraced: {cc.summary()}"


def test_batchserver_solve_is_nan_clean_under_sanitizer():
    # the serving path end to end with tripwires armed: recovery of an
    # exactly-sparse problem must not manufacture a single NaN
    phi, Y = _problem(m=45, n=89, s=3, b=2, seed=3)
    with sanitize():
        srv = BatchServer(phi, s=3, n_iters=25, n_devices=1, with_trace=True)
        res = srv.submit(Y)
        jax.block_until_ready(res.x)
    assert np.isfinite(np.asarray(res.x)).all()
